"""Batched serving driver: continuous batched decode over a prompt pool.

Demonstrates the inference side of the framework: prefill a batch of
requests, then decode with ``serve_step`` (single compiled step, KV cache
donated) while tracking per-request latency and aggregate tokens/s.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import model as model_mod
from repro.models.model import RunOptions


def run_serving(arch: str = "gemma2-2b", *, batch: int = 4,
                prompt_len: int = 64, gen_len: int = 32,
                full: bool = False, seed: int = 0, greedy: bool = True,
                verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if not full:
        cfg = cfg.reduced()
    max_len = prompt_len + gen_len
    opts = RunOptions(q_chunk=min(64, prompt_len), kv_chunk=min(64, prompt_len))

    rng = jax.random.PRNGKey(seed)
    params = model_mod.init_params(rng, cfg)
    serve_step = jax.jit(make_serve_step(cfg, opts), donate_argnums=(1,))

    # build prompts + a max_len cache, prefill by decoding the prompt
    # token-by-token is wasteful; use prefill for the prompt then extend the
    # cache by decode steps.
    if cfg.embed_inputs:
        prompts = jax.random.randint(rng, (batch, prompt_len), 0,
                                     cfg.vocab_size)
        tok0 = prompts[:, -1:]
    else:
        prompts = jax.random.normal(rng, (batch, prompt_len, cfg.d_model),
                                    cfg.cdtype) * 0.02
        tok0 = prompts[:, -1:]

    # decode-only cache covering max_len; replay the prompt through
    # serve_step to fill it (keeps one compiled path; prefill_step exists
    # for the prefill-shape dry-run cells)
    cache = model_mod.init_cache(cfg, batch, max_len)
    t0 = time.perf_counter()
    logits = None
    for pos in range(prompt_len):
        tok = prompts[:, pos:pos + 1]
        logits, cache = serve_step(params, cache, tok, jnp.int32(pos))
    t_prefill = time.perf_counter() - t0

    # generation loop
    out_tokens = []
    tok = tok0
    lat = []
    t_gen0 = time.perf_counter()
    for i in range(gen_len):
        t1 = time.perf_counter()
        pos = prompt_len + i
        if cfg.embed_inputs:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None] if greedy \
                else jax.random.categorical(
                    jax.random.PRNGKey(i), logits[:, -1])[:, None]
            tok = nxt
        logits, cache = serve_step(params, cache, tok, jnp.int32(pos))
        logits.block_until_ready()
        lat.append(time.perf_counter() - t1)
        if cfg.embed_inputs:
            out_tokens.append(np.asarray(tok)[:, 0])
    t_gen = time.perf_counter() - t_gen0

    result = {
        "arch": arch,
        "batch": batch,
        "prompt_len": prompt_len,
        "gen_len": gen_len,
        "prefill_s": t_prefill,
        "decode_tokens_per_s": batch * gen_len / t_gen,
        "decode_p50_ms": float(np.median(lat) * 1e3),
        "decode_p99_ms": float(np.percentile(lat, 99) * 1e3),
        "sample": (np.stack(out_tokens, 1)[0][:8].tolist()
                   if out_tokens else None),
    }
    if verbose:
        print(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser(description="batched serving demo")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run_serving(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_len=args.gen_len, full=args.full)


if __name__ == "__main__":
    main()
