"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis
    is the HSDP replica axis (paper §3.1 Table 5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A trivial 1-device mesh for CPU smoke runs through the same API."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
