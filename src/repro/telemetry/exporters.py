"""Per-node exporter models (dcgm-exporter / node_exporter / all-smi /
Backend.AI scheduler metrics).

Each exporter emits the metric vocabulary the paper's analysis actually used
(§4.1 figures) with realistic healthy baselines, plus failure-signature hooks
that the failure injector drives:

* NVLink/Bus fault (XID 79/145/149): node_intr_total 30s-increment collapses
  ~300K -> 70-100K; node_procs_running -> 0 (paper Fig 2).
* ECC (XID 94): NFS GETATTR response-time and pgpgout surge (paper Fig 3);
  DCGM uncorrectable row-remap counter steps up (paper Fig 4).
* Gradual precursors (the 2/10 pre-XID cases): accelerating correctable
  row-remaps and creeping temperature before the XID fires.
* Fail-slow: GPU util dips + per-step time inflation without any XID.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.failures import FailureEvent
from repro.telemetry.registry import MetricMeta, MetricRegistry

# The full production pipeline carries ~751 metric names, ~305 analysis-
# relevant (paper §3.4).  We model the ~30 the analyses actually read and
# pad the registry with inert extras so detector cost/FP behaviour is
# realistic at the true metric count.
N_PAD_METRICS = 275

CORE_METRICS = [
    # node_exporter
    ("node_intr_total", "counter", "node"),
    ("node_procs_running", "gauge", "node"),
    ("node_procs_blocked", "gauge", "node"),
    ("node_vmstat_pgpgout", "counter", "node"),
    ("node_vmstat_pgpgin", "counter", "node"),
    ("node_memory_MemAvailable_bytes", "gauge", "node"),
    ("node_memory_Dirty_bytes", "gauge", "node"),
    ("node_memory_Writeback_bytes", "gauge", "node"),
    ("node_mountstats_nfs_operations_response_time_seconds_total:GETATTR",
     "counter", "node"),
    ("node_mountstats_nfs_operations_queue_time_seconds_total:WRITE",
     "counter", "node"),
    ("node_mountstats_nfs_read_bytes_total", "counter", "node"),
    ("node_mountstats_nfs_write_bytes_total", "counter", "node"),
    ("node_network_transmit_bytes_total", "counter", "node"),
    ("node_network_receive_bytes_total", "counter", "node"),
    ("node_infiniband_port_data_transmitted_bytes_total", "counter", "node"),
    ("node_infiniband_port_data_received_bytes_total", "counter", "node"),
    ("node_sockstat_TCP_alloc", "gauge", "node"),
    ("node_context_switches_total", "counter", "node"),
    # dcgm-exporter
    ("DCGM_FI_DEV_GPU_UTIL", "gauge", "dcgm"),
    ("DCGM_FI_DEV_GPU_TEMP", "gauge", "dcgm"),
    ("DCGM_FI_DEV_POWER_USAGE", "gauge", "dcgm"),
    ("DCGM_FI_DEV_FB_USED", "gauge", "dcgm"),
    ("DCGM_FI_DEV_SM_CLOCK", "gauge", "dcgm"),
    ("DCGM_FI_DEV_ROW_REMAP_UNCORRECTABLE", "counter", "dcgm"),
    ("DCGM_FI_DEV_ROW_REMAP_CORRECTABLE", "counter", "dcgm"),
    ("DCGM_FI_DEV_XID_ERRORS", "gauge", "dcgm"),
    ("DCGM_FI_DEV_NVLINK_BANDWIDTH_TOTAL", "counter", "dcgm"),
    # all-smi
    ("all_smi_gpu_power_watts", "gauge", "all_smi"),
    ("all_smi_sys_memory_used_bytes", "gauge", "all_smi"),
    # Backend.AI scheduler
    ("backendai_rpc_latency_ms", "gauge", "backendai"),
    ("backendai_active_sessions", "gauge", "backendai"),
    ("backendai_async_task_count", "gauge", "backendai"),
    ("backendai_agent_heartbeat_age_s", "gauge", "backendai"),
]


@dataclass
class NodeState:
    """What the simulated node is doing right now (drives exporter values)."""
    training: bool = True
    checkpointing: bool = False
    loading: bool = False
    down: bool = False
    slow_factor: float = 1.0


class ExporterSuite:
    """Generates one scrape tick of all metrics for all nodes."""

    def __init__(self, n_nodes: int, seed: int = 0):
        self.n = n_nodes
        self.rng = np.random.default_rng(seed)
        self.reg = MetricRegistry(n_nodes)
        for name, kind, exp in CORE_METRICS:
            self.reg.register(MetricMeta(name, kind, exp))
        for i in range(N_PAD_METRICS):
            self.reg.register(MetricMeta(f"aux_metric_{i:03d}", "gauge", "node"))
        # persistent per-node counters
        self.remap_corr = np.zeros(n_nodes)
        self.remap_uncorr = np.zeros(n_nodes)
        self.accel_nodes: Dict[int, tuple] = {}   # node -> (onset_h, until_h)

    # -- failure signature hooks (called by the cluster sim) ---------------

    def begin_gradual_precursor(self, node: int, t_h: float,
                                until_h: float = float("inf")):
        self.accel_nodes[node] = (t_h, until_h)

    def tick(self, t_h: float, states: List[NodeState],
             failures_now: List[FailureEvent]) -> Dict[str, np.ndarray]:
        """Produce one 30-second scrape snapshot at time ``t_h`` (hours)."""
        n = self.n
        r = self.rng
        up = np.array([not s.down for s in states], dtype=float)
        training = np.array([s.training and not s.down for s in states],
                            dtype=float)
        ckpt = np.array([s.checkpointing for s in states], dtype=float)
        load = np.array([s.loading for s in states], dtype=float)
        slow = np.array([s.slow_factor for s in states])

        v: Dict[str, np.ndarray] = {}
        # host interrupts: ~300K/30s while the GPUs generate work
        v["node_intr_total"] = (300e3 * training / slow + 40e3 * up
                                + r.normal(0, 8e3, n)) * up
        v["node_procs_running"] = (34 * training + 2 * up
                                   + r.integers(0, 3, n)) * up
        v["node_procs_blocked"] = (r.integers(0, 2, n) + 30 * ckpt) * up
        v["node_vmstat_pgpgout"] = (2e4 + 3e6 * ckpt + r.normal(0, 5e3, n)) * up
        v["node_vmstat_pgpgin"] = (2e4 + 5e6 * load + r.normal(0, 5e3, n)) * up
        v["node_memory_MemAvailable_bytes"] = \
            (1.9e12 - 1e11 * training + r.normal(0, 2e10, n)) * up
        v["node_memory_Dirty_bytes"] = (1e8 + 2.4e10 * ckpt
                                        + r.normal(0, 3e7, n)) * up
        v["node_memory_Writeback_bytes"] = (5e6 + 1.2e10 * ckpt
                                            + r.normal(0, 1e6, n)) * up
        v["node_mountstats_nfs_operations_response_time_seconds_total:GETATTR"] = \
            (0.05 + 0.4 * load + r.exponential(0.01, n)) * up
        v["node_mountstats_nfs_operations_queue_time_seconds_total:WRITE"] = \
            (0.01 + 45.0 * ckpt + r.exponential(0.005, n)) * up
        v["node_mountstats_nfs_read_bytes_total"] = \
            (1e6 + 4.2e9 * 30 * load + r.normal(0, 1e5, n)).clip(0) * up
        v["node_mountstats_nfs_write_bytes_total"] = \
            (1e5 + 0.6e9 * 30 * ckpt + r.normal(0, 1e4, n)).clip(0) * up
        v["node_network_transmit_bytes_total"] = (2e8 + r.normal(0, 1e7, n)) * up
        v["node_network_receive_bytes_total"] = (2e8 + r.normal(0, 1e7, n)) * up
        ib = 30 * 100e9 * training / slow         # ~100 GB/s sustained DP traffic
        v["node_infiniband_port_data_transmitted_bytes_total"] = \
            (ib + r.normal(0, 1e10, n)).clip(0) * up
        v["node_infiniband_port_data_received_bytes_total"] = \
            (ib + r.normal(0, 1e10, n)).clip(0) * up
        v["node_sockstat_TCP_alloc"] = (180 + 40 * load
                                        + r.integers(-10, 10, n)) * up
        v["node_context_switches_total"] = (8e5 * training / slow + 1e5 * up
                                            + r.normal(0, 2e4, n)) * up
        v["DCGM_FI_DEV_GPU_UTIL"] = (99.3 * training / slow - 60 * ckpt
                                     - 80 * load + r.normal(0, 0.4, n)).clip(0, 100) * up
        v["DCGM_FI_DEV_GPU_TEMP"] = (62 * training + 35
                                     + r.normal(0, 1.5, n)) * up
        v["DCGM_FI_DEV_POWER_USAGE"] = (950 * training / slow + 120
                                        + r.normal(0, 25, n)) * up
        v["DCGM_FI_DEV_FB_USED"] = (1.66e11 * training + 2e9) * up
        v["DCGM_FI_DEV_SM_CLOCK"] = (1980 * training + 210
                                     + r.normal(0, 20, n)) * up
        v["DCGM_FI_DEV_NVLINK_BANDWIDTH_TOTAL"] = \
            (30 * 4.5e11 * training / slow + r.normal(0, 1e11, n)).clip(0) * up
        v["all_smi_gpu_power_watts"] = v["DCGM_FI_DEV_POWER_USAGE"] * 1.02
        v["all_smi_sys_memory_used_bytes"] = (2.1e11 + 2.4e10 * ckpt
                                              + r.normal(0, 5e9, n)) * up
        v["backendai_rpc_latency_ms"] = (3 + r.exponential(1.5, n)) * up
        v["backendai_active_sessions"] = training
        v["backendai_async_task_count"] = (12 + 30 * ckpt
                                           + r.integers(0, 5, n)) * up
        v["backendai_agent_heartbeat_age_s"] = (r.uniform(0, 35, n)) \
            + 600 * (1 - up)

        # gradual precursors (accelerating correctable remaps + thermal /
        # clock / latency drift, paper Fig 4): multiple metrics deviate so
        # the multi-signal vote can fire BEFORE the XID for long-lead cases
        for node, (onset, until) in self.accel_nodes.items():
            if onset <= t_h < until:
                prog = min((t_h - onset) / 0.5, 4.0)
                self.remap_corr[node] += 0.4 * (1 + (t_h - onset)) ** 1.5
                v["DCGM_FI_DEV_GPU_TEMP"][node] += 5.0 * prog
                v["DCGM_FI_DEV_POWER_USAGE"][node] += 60.0 * prog
                v["DCGM_FI_DEV_SM_CLOCK"][node] -= 30.0 * prog
                v["backendai_rpc_latency_ms"][node] += 4.0 * prog
        # background slow accumulation
        self.remap_corr += r.random(n) < 0.001

        xid_now = np.zeros(n)
        for ev in failures_now:
            node = ev.node
            if ev.kind == "xid":
                xid_now[node] = ev.xid
                if ev.xid in (79, 145, 149):          # NVLink / bus fault
                    v["node_intr_total"][node] = r.uniform(70e3, 100e3)
                    v["node_procs_running"][node] = 0.0
                    v["DCGM_FI_DEV_NVLINK_BANDWIDTH_TOTAL"][node] = 0.0
                    v["DCGM_FI_DEV_GPU_UTIL"][node] = 0.0
                elif ev.xid == 94:                     # ECC
                    v["node_mountstats_nfs_operations_response_time_seconds_total:GETATTR"][node] += 3.0
                    v["node_vmstat_pgpgout"][node] += 4e6
                    self.remap_uncorr[node] += r.integers(1, 3)
                    v["node_procs_running"][node] = 0.0
                elif ev.xid == 119:                    # GSP RPC timeout
                    v["backendai_rpc_latency_ms"][node] += 500
                    v["DCGM_FI_DEV_SM_CLOCK"][node] = 210
                    v["DCGM_FI_DEV_GPU_UTIL"][node] = 0.0
                else:                                  # 31/43 app-level
                    # dead worker: host stops generating device-driven load
                    v["node_procs_running"][node] = 0.0
                    v["DCGM_FI_DEV_GPU_UTIL"][node] = 0.0
                    v["node_intr_total"][node] = r.uniform(90e3, 130e3)
                    v["node_context_switches_total"][node] = r.uniform(1e5, 2e5)
                    v["DCGM_FI_DEV_POWER_USAGE"][node] = r.uniform(120, 180)
                    v["DCGM_FI_DEV_NVLINK_BANDWIDTH_TOTAL"][node] = 0.0
            elif ev.kind == "unreachable":
                for key in v:
                    v[key][node] = 0.0
                v["backendai_agent_heartbeat_age_s"][node] = 600.0

        v["DCGM_FI_DEV_XID_ERRORS"] = xid_now
        v["DCGM_FI_DEV_ROW_REMAP_CORRECTABLE"] = self.remap_corr.copy()
        v["DCGM_FI_DEV_ROW_REMAP_UNCORRECTABLE"] = self.remap_uncorr.copy()

        # inert padding metrics (white noise — detector must not alarm on them)
        for i in range(N_PAD_METRICS):
            v[f"aux_metric_{i:03d}"] = r.normal(50, 5, n) * up
        return v
