"""Per-node exporter models (dcgm-exporter / node_exporter / all-smi /
Backend.AI scheduler metrics).

Each exporter emits the metric vocabulary the paper's analysis actually used
(§4.1 figures) with realistic healthy baselines, plus failure-signature hooks
that the failure injector drives:

* NVLink/Bus fault (XID 79/145/149): node_intr_total 30s-increment collapses
  ~300K -> 70-100K; node_procs_running -> 0 (paper Fig 2).
* ECC (XID 94): NFS GETATTR response-time and pgpgout surge (paper Fig 3);
  DCGM uncorrectable row-remap counter steps up (paper Fig 4).
* Gradual precursors (the 2/10 pre-XID cases): accelerating correctable
  row-remaps and creeping temperature before the XID fires.
* Fail-slow: GPU util dips + per-step time inflation without any XID.

Generation is batched: ``tick_batch`` produces (n_ticks, n_nodes) arrays for
a whole span of scrape ticks in one set of numpy draws, which is what makes
the event-driven cluster simulation fast (the per-tick ``tick`` wrapper is
kept for single-scrape callers and tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.failures import FailureEvent, onset_progress
from repro.storage.fabric import StorageFabric
from repro.telemetry.registry import MetricMeta, MetricRegistry

# The full production pipeline carries ~751 metric names, ~305 analysis-
# relevant (paper §3.4).  We model the ~30 the analyses actually read and
# pad the registry with inert extras so detector cost/FP behaviour is
# realistic at the true metric count.  Sweeps that only need F3/F4 can
# shrink the pad (``n_pad``) to bound the time-series store footprint.
N_PAD_METRICS = 275

CORE_METRICS = [
    # node_exporter
    ("node_intr_total", "counter", "node"),
    ("node_procs_running", "gauge", "node"),
    ("node_procs_blocked", "gauge", "node"),
    ("node_vmstat_pgpgout", "counter", "node"),
    ("node_vmstat_pgpgin", "counter", "node"),
    ("node_memory_MemAvailable_bytes", "gauge", "node"),
    ("node_memory_Dirty_bytes", "gauge", "node"),
    ("node_memory_Writeback_bytes", "gauge", "node"),
    ("node_mountstats_nfs_operations_response_time_seconds_total:GETATTR",
     "counter", "node"),
    ("node_mountstats_nfs_operations_queue_time_seconds_total:WRITE",
     "counter", "node"),
    ("node_mountstats_nfs_read_bytes_total", "counter", "node"),
    ("node_mountstats_nfs_write_bytes_total", "counter", "node"),
    # storage-fabric F2 signals: RPC queue depth and transport backlog
    # rise together during save/load bursts (paper §4.2.5)
    ("node_mountstats_nfs_rpc_queue_depth", "gauge", "node"),
    ("node_netstat_Tcp_transport_backlog_bytes", "gauge", "node"),
    ("node_network_transmit_bytes_total", "counter", "node"),
    ("node_network_receive_bytes_total", "counter", "node"),
    ("node_infiniband_port_data_transmitted_bytes_total", "counter", "node"),
    ("node_infiniband_port_data_received_bytes_total", "counter", "node"),
    ("node_sockstat_TCP_alloc", "gauge", "node"),
    ("node_context_switches_total", "counter", "node"),
    # dcgm-exporter
    ("DCGM_FI_DEV_GPU_UTIL", "gauge", "dcgm"),
    ("DCGM_FI_DEV_GPU_TEMP", "gauge", "dcgm"),
    ("DCGM_FI_DEV_POWER_USAGE", "gauge", "dcgm"),
    ("DCGM_FI_DEV_FB_USED", "gauge", "dcgm"),
    ("DCGM_FI_DEV_SM_CLOCK", "gauge", "dcgm"),
    ("DCGM_FI_DEV_ROW_REMAP_UNCORRECTABLE", "counter", "dcgm"),
    ("DCGM_FI_DEV_ROW_REMAP_CORRECTABLE", "counter", "dcgm"),
    ("DCGM_FI_DEV_XID_ERRORS", "gauge", "dcgm"),
    ("DCGM_FI_DEV_NVLINK_BANDWIDTH_TOTAL", "counter", "dcgm"),
    # all-smi
    ("all_smi_gpu_power_watts", "gauge", "all_smi"),
    ("all_smi_sys_memory_used_bytes", "gauge", "all_smi"),
    # Backend.AI scheduler
    ("backendai_rpc_latency_ms", "gauge", "backendai"),
    ("backendai_active_sessions", "gauge", "backendai"),
    ("backendai_async_task_count", "gauge", "backendai"),
    ("backendai_agent_heartbeat_age_s", "gauge", "backendai"),
]


@dataclass
class NodeState:
    """What the simulated node is doing right now (drives exporter values)."""
    training: bool = True
    checkpointing: bool = False
    loading: bool = False
    down: bool = False
    slow_factor: float = 1.0


@dataclass
class NodeStateBatch:
    """Node activity over a span of scrape ticks, as (n_ticks, n_nodes)
    arrays.  Within a span between discrete events the per-node role is
    constant, so builders usually broadcast a single (n_nodes,) row."""
    training: np.ndarray
    checkpointing: np.ndarray
    loading: np.ndarray
    down: np.ndarray
    slow: np.ndarray

    @classmethod
    def from_states(cls, states: Sequence[NodeState]) -> "NodeStateBatch":
        """One tick (T=1) from a list of per-node states — a single pass
        over the states into one (5, n) block, then unstacked."""
        block = np.array([(s.training, s.checkpointing, s.loading,
                           s.down, s.slow_factor) for s in states],
                         dtype=float).T.reshape(5, 1, -1)
        return cls(training=block[0], checkpointing=block[1],
                   loading=block[2], down=block[3], slow=block[4])

    @classmethod
    def constant(cls, n_ticks: int, n_nodes: int, *,
                 training=None, checkpointing=None, loading=None,
                 down=None, slow=None) -> "NodeStateBatch":
        """Broadcast per-node rows (or tick-varying arrays) to (T, n)."""
        def expand(x, fill=0.0):
            if x is None:
                return np.full((n_ticks, n_nodes), fill)
            x = np.asarray(x, dtype=float)
            return np.broadcast_to(x, (n_ticks, n_nodes)).copy() \
                if x.ndim < 2 else x.astype(float)
        return cls(training=expand(training),
                   checkpointing=expand(checkpointing),
                   loading=expand(loading),
                   down=expand(down),
                   slow=expand(slow, fill=1.0))


class ExporterSuite:
    """Generates scrape ticks of all metrics for all nodes."""

    def __init__(self, n_nodes: int, seed: int = 0,
                 n_pad: int = N_PAD_METRICS,
                 storage_levels: Optional[Dict[str, float]] = None):
        self.n = n_nodes
        self.n_pad = n_pad
        # characteristic RPC queue depth / transport backlog while a
        # save/load is in flight, from the shared storage fabric at the
        # campaign's gang fanin (paper-default fabric when not supplied)
        self.storage_levels = storage_levels \
            or StorageFabric().telemetry_levels(60)
        self.rng = np.random.default_rng(seed)
        self.reg = MetricRegistry(n_nodes)
        for name, kind, exp in CORE_METRICS:
            self.reg.register(MetricMeta(name, kind, exp))
        for i in range(n_pad):
            self.reg.register(MetricMeta(f"aux_metric_{i:03d}", "gauge", "node"))
        # persistent per-node counters
        self.remap_corr = np.zeros(n_nodes)
        self.remap_uncorr = np.zeros(n_nodes)
        self.accel_nodes: Dict[int, tuple] = {}   # node -> (onset_h, until_h)
        # infra fault band windows (registered at campaign setup)
        self.degradations: List[tuple] = []   # (node, t0, t1, sev, kind,
                                              #  onset)
        self.outages: List[tuple] = []        # (t0, t1) control-plane blind

    # -- failure signature hooks (called by the cluster sim) ---------------

    def begin_gradual_precursor(self, node: int, t_h: float,
                                until_h: float = float("inf")):
        self.accel_nodes[node] = (t_h, until_h)

    def begin_degradation(self, node: int, t0_h: float, t1_h: float,
                          severity: float, kind: str, onset: str):
        """Register a degrade-band window ([t0, t1), net/resource kind)."""
        self.degradations.append((node, t0_h, t1_h, severity, kind, onset))

    def begin_link_degradation(self, nodes, t0_h: float, t1_h: float,
                               severity: float, onset: str = "spike"):
        """Correlated fault band: one fabric event (switch degradation or
        a dns flap's affected links) degrades *every* listed node for the
        same window.  Registers the shared window per node through the
        net-degrade overlay — deterministic and RNG-free, so gang members
        co-degrade with the exact correlated timing the detector's
        cross-node pass keys on."""
        for node in nodes:
            self.begin_degradation(int(node), t0_h, t1_h, severity,
                                   "net_degrade", onset)

    def begin_outage(self, t0_h: float, t1_h: float):
        """Register a control-plane blind window (scheduler outage)."""
        self.outages.append((t0_h, t1_h))

    # -- single-tick compatibility wrapper ---------------------------------

    def tick(self, t_h: float, states: List[NodeState],
             failures_now: List[FailureEvent]) -> Dict[str, np.ndarray]:
        """Produce one 30-second scrape snapshot at time ``t_h`` (hours)."""
        batch = NodeStateBatch.from_states(states)
        out = self.tick_batch(np.array([t_h]), batch,
                              [(0, ev) for ev in failures_now])
        return {k: v[0] for k, v in out.items()}

    # -- batched generation -------------------------------------------------

    def tick_batch(self, ts: np.ndarray, batch: NodeStateBatch,
                   failure_rows: Sequence[Tuple[int, FailureEvent]] = ()
                   ) -> Dict[str, np.ndarray]:
        """Produce ``len(ts)`` scrape snapshots at once.

        ``ts``: (T,) scrape times in hours; ``batch``: (T, n) activity masks;
        ``failure_rows``: (row_index, event) pairs pinning each failure's
        abrupt signature to the scrape tick it lands on.  Returns
        metric -> (T, n) arrays.  Persistent counters (row-remaps) advance
        by cumulative sums so per-tick semantics match the serial loop.
        """
        n = self.n
        r = self.rng
        ts = np.asarray(ts, dtype=float)
        T = len(ts)
        up = 1.0 - np.asarray(batch.down, dtype=float)
        training = np.asarray(batch.training, dtype=float) * up
        ckpt = np.asarray(batch.checkpointing, dtype=float)
        load = np.asarray(batch.loading, dtype=float)
        slow = np.asarray(batch.slow, dtype=float)
        shape = (T, n)

        v: Dict[str, np.ndarray] = {}
        # host interrupts: ~300K/30s while the GPUs generate work
        v["node_intr_total"] = (300e3 * training / slow + 40e3 * up
                                + r.normal(0, 8e3, shape)) * up
        v["node_procs_running"] = (34 * training + 2 * up
                                   + r.integers(0, 3, shape)) * up
        v["node_procs_blocked"] = (r.integers(0, 2, shape) + 30 * ckpt) * up
        v["node_vmstat_pgpgout"] = (2e4 + 3e6 * ckpt
                                    + r.normal(0, 5e3, shape)) * up
        v["node_vmstat_pgpgin"] = (2e4 + 5e6 * load
                                   + r.normal(0, 5e3, shape)) * up
        v["node_memory_MemAvailable_bytes"] = \
            (1.9e12 - 1e11 * training + r.normal(0, 2e10, shape)) * up
        v["node_memory_Dirty_bytes"] = (1e8 + 2.4e10 * ckpt
                                        + r.normal(0, 3e7, shape)) * up
        v["node_memory_Writeback_bytes"] = (5e6 + 1.2e10 * ckpt
                                            + r.normal(0, 1e6, shape)) * up
        v["node_mountstats_nfs_operations_response_time_seconds_total:GETATTR"] = \
            (0.05 + 0.4 * load + r.exponential(0.01, shape)) * up
        v["node_mountstats_nfs_operations_queue_time_seconds_total:WRITE"] = \
            (0.01 + 45.0 * ckpt + r.exponential(0.005, shape)) * up
        v["node_mountstats_nfs_read_bytes_total"] = \
            (1e6 + 4.2e9 * 30 * load + r.normal(0, 1e5, shape)).clip(0) * up
        v["node_mountstats_nfs_write_bytes_total"] = \
            (1e5 + 0.6e9 * 30 * ckpt + r.normal(0, 1e4, shape)).clip(0) * up
        # fabric F2 signals: queue depth and backlog rise TOGETHER during
        # save/load bursts; fail-slow nodes sit above their peers (slow >= 1)
        lv = self.storage_levels
        v["node_mountstats_nfs_rpc_queue_depth"] = \
            ((2.0 + lv["save_queue_depth"] * ckpt
              + lv["load_queue_depth"] * load
              + r.exponential(1.0, shape)) * slow) * up
        v["node_netstat_Tcp_transport_backlog_bytes"] = \
            ((1e4 + lv["save_backlog_bytes"] * ckpt
              + lv["load_backlog_bytes"] * load
              + r.exponential(5e3, shape)) * slow) * up
        v["node_network_transmit_bytes_total"] = \
            (2e8 + r.normal(0, 1e7, shape)) * up
        v["node_network_receive_bytes_total"] = \
            (2e8 + r.normal(0, 1e7, shape)) * up
        ib = 30 * 100e9 * training / slow         # ~100 GB/s sustained DP traffic
        v["node_infiniband_port_data_transmitted_bytes_total"] = \
            (ib + r.normal(0, 1e10, shape)).clip(0) * up
        v["node_infiniband_port_data_received_bytes_total"] = \
            (ib + r.normal(0, 1e10, shape)).clip(0) * up
        v["node_sockstat_TCP_alloc"] = (180 + 40 * load
                                        + r.integers(-10, 10, shape)) * up
        v["node_context_switches_total"] = (8e5 * training / slow + 1e5 * up
                                            + r.normal(0, 2e4, shape)) * up
        v["DCGM_FI_DEV_GPU_UTIL"] = \
            (99.3 * training / slow - 60 * ckpt - 80 * load
             + r.normal(0, 0.4, shape)).clip(0, 100) * up
        v["DCGM_FI_DEV_GPU_TEMP"] = (62 * training + 35
                                     + r.normal(0, 1.5, shape)) * up
        v["DCGM_FI_DEV_POWER_USAGE"] = (950 * training / slow + 120
                                        + r.normal(0, 25, shape)) * up
        v["DCGM_FI_DEV_FB_USED"] = (1.66e11 * training + 2e9) * up
        v["DCGM_FI_DEV_SM_CLOCK"] = (1980 * training + 210
                                     + r.normal(0, 20, shape)) * up
        v["DCGM_FI_DEV_NVLINK_BANDWIDTH_TOTAL"] = \
            (30 * 4.5e11 * training / slow + r.normal(0, 1e11, shape)).clip(0) * up
        v["all_smi_gpu_power_watts"] = v["DCGM_FI_DEV_POWER_USAGE"] * 1.02
        v["all_smi_sys_memory_used_bytes"] = (2.1e11 + 2.4e10 * ckpt
                                              + r.normal(0, 5e9, shape)) * up
        v["backendai_rpc_latency_ms"] = (3 + r.exponential(1.5, shape)) * up
        v["backendai_active_sessions"] = training
        v["backendai_async_task_count"] = (12 + 30 * ckpt
                                           + r.integers(0, 5, shape)) * up
        v["backendai_agent_heartbeat_age_s"] = r.uniform(0, 35, shape) \
            + 600 * (1 - up)

        # persistent counters: per-tick increments, then a cumulative sum so
        # every tick of the span observes the running value
        corr_inc = (r.random(shape) < 0.001).astype(float)
        uncorr_inc = np.zeros(shape)

        # gradual precursors (accelerating correctable remaps + thermal /
        # clock / latency drift, paper Fig 4): multiple metrics deviate so
        # the multi-signal vote can fire BEFORE the XID for long-lead cases
        for node, (onset, until) in self.accel_nodes.items():
            active = (ts >= onset) & (ts < until)
            if not active.any():
                continue
            # clamp dt at 0 outside the window: a negative base under the
            # fractional power would give NaN, and NaN * 0-mask is still NaN
            dt = np.where(active, ts - onset, 0.0)
            prog = np.minimum(dt / 0.5, 4.0) * active
            corr_inc[:, node] += 0.4 * (1 + dt) ** 1.5 * active
            v["DCGM_FI_DEV_GPU_TEMP"][:, node] += 5.0 * prog
            v["DCGM_FI_DEV_POWER_USAGE"][:, node] += 60.0 * prog
            v["DCGM_FI_DEV_SM_CLOCK"][:, node] -= 30.0 * prog
            v["backendai_rpc_latency_ms"][:, node] += 4.0 * prog

        # degrade-band windows: deterministic overlays on the drawn arrays
        # (no extra RNG, so campaigns without infra faults stay bit-
        # identical).  Each kind deviates >= 5 node-local metrics so the
        # detector's min_signals vote can fire; gang-wide components are
        # uniform across nodes, which peer z-scoring is deliberately
        # silent on (attribution needs the node-local signals)
        for node, d0, d1, sev, kind, onset in self.degradations:
            prog = onset_progress(ts, d0, d1, onset)
            if not prog.any():
                continue
            sevx = (sev - 1.0) * prog * up[:, node]
            if kind == "net_degrade":
                qd = lv.get("degrade_queue_depth", 60.0)
                bb = lv.get("degrade_backlog_bytes", 2e7)
                v["node_mountstats_nfs_rpc_queue_depth"][:, node] += \
                    qd * sevx
                v["node_netstat_Tcp_transport_backlog_bytes"][:, node] += \
                    bb * sevx
                v["backendai_rpc_latency_ms"][:, node] += 50.0 * sevx
                v["node_sockstat_TCP_alloc"][:, node] += 400.0 * sevx
                v["node_mountstats_nfs_operations_response_time_seconds_total:GETATTR"][:, node] += 1.5 * sevx
                # collective step time inflates for the whole gang: every
                # node's transport backlog rises with the degraded peer
                v["node_netstat_Tcp_transport_backlog_bytes"] += \
                    (0.01 * bb * (sev - 1.0) * prog)[:, None] * up
            else:                              # resource_exhaust
                v["node_memory_MemAvailable_bytes"][:, node] -= 9e11 * sevx
                v["all_smi_sys_memory_used_bytes"][:, node] += 1.5e11 * sevx
                v["node_vmstat_pgpgout"][:, node] += 3e5 * sevx
                v["node_context_switches_total"][:, node] += 5e5 * sevx
                v["DCGM_FI_DEV_GPU_UTIL"][:, node] -= 15.0 * sevx
        for o0, o1 in self.outages:
            mask = ((ts >= o0) & (ts < o1)).astype(float)
            if mask.any():
                # scheduler outage: agent heartbeats age out gang-wide
                # (uniform -> no per-node alarm; the control plane itself
                # is what goes dark)
                v["backendai_agent_heartbeat_age_s"] += \
                    (300.0 * mask)[:, None] * up

        # abrupt failure signatures, pinned to their scrape tick
        xid_now = np.zeros(shape)
        for row, ev in failure_rows:
            node = ev.node
            if ev.kind == "xid":
                xid_now[row, node] = ev.xid
                if ev.xid in (79, 145, 149):          # NVLink / bus fault
                    v["node_intr_total"][row, node] = r.uniform(70e3, 100e3)
                    v["node_procs_running"][row, node] = 0.0
                    v["DCGM_FI_DEV_NVLINK_BANDWIDTH_TOTAL"][row, node] = 0.0
                    v["DCGM_FI_DEV_GPU_UTIL"][row, node] = 0.0
                elif ev.xid == 94:                     # ECC
                    v["node_mountstats_nfs_operations_response_time_seconds_total:GETATTR"][row, node] += 3.0
                    v["node_vmstat_pgpgout"][row, node] += 4e6
                    uncorr_inc[row, node] += r.integers(1, 3)
                    v["node_procs_running"][row, node] = 0.0
                elif ev.xid == 119:                    # GSP RPC timeout
                    v["backendai_rpc_latency_ms"][row, node] += 500
                    v["DCGM_FI_DEV_SM_CLOCK"][row, node] = 210
                    v["DCGM_FI_DEV_GPU_UTIL"][row, node] = 0.0
                else:                                  # 31/43 app-level
                    # dead worker: host stops generating device-driven load
                    v["node_procs_running"][row, node] = 0.0
                    v["DCGM_FI_DEV_GPU_UTIL"][row, node] = 0.0
                    v["node_intr_total"][row, node] = r.uniform(90e3, 130e3)
                    v["node_context_switches_total"][row, node] = \
                        r.uniform(1e5, 2e5)
                    v["DCGM_FI_DEV_POWER_USAGE"][row, node] = r.uniform(120, 180)
                    v["DCGM_FI_DEV_NVLINK_BANDWIDTH_TOTAL"][row, node] = 0.0
            elif ev.kind == "unreachable":
                for key in v:
                    v[key][row, node] = 0.0
                v["backendai_agent_heartbeat_age_s"][row, node] = 600.0

        v["DCGM_FI_DEV_XID_ERRORS"] = xid_now
        corr_series = self.remap_corr[None, :] + np.cumsum(corr_inc, axis=0)
        uncorr_series = self.remap_uncorr[None, :] + np.cumsum(uncorr_inc,
                                                              axis=0)
        self.remap_corr = corr_series[-1].copy()
        self.remap_uncorr = uncorr_series[-1].copy()
        v["DCGM_FI_DEV_ROW_REMAP_CORRECTABLE"] = corr_series
        v["DCGM_FI_DEV_ROW_REMAP_UNCORRECTABLE"] = uncorr_series

        # inert padding metrics (white noise — detector must not alarm on
        # them); one float32 draw for the whole pad block (the detector's
        # robust z-scores don't need float64 on ~N(50,5) noise)
        if self.n_pad:
            pads = 5.0 * r.standard_normal((self.n_pad, T, n),
                                           dtype=np.float32) + np.float32(50.0)
            pads *= up[None].astype(np.float32)
            for i in range(self.n_pad):
                v[f"aux_metric_{i:03d}"] = pads[i]
        return v
