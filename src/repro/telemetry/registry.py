"""Prometheus-style metric registry (counters + gauges) with a scrape loop.

The production pipeline in the paper scrapes 4 exporters x 63 nodes at 30 s
intervals into VictoriaMetrics (~751 unique metric names).  This module is
the in-process stand-in: exporters write samples, the registry scrapes into
the time-series store, and the precursor detector reads windows back.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

SCRAPE_INTERVAL_S = 30.0


@dataclass
class MetricMeta:
    name: str
    kind: str            # "counter" | "gauge"
    exporter: str        # dcgm | node | all_smi | backendai
    help: str = ""


class MetricRegistry:
    """Holds current values per (metric, node) and scrapes them into a store."""

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.meta: Dict[str, MetricMeta] = {}
        self.values: Dict[str, np.ndarray] = {}

    def register(self, meta: MetricMeta):
        if meta.name in self.meta:
            return
        self.meta[meta.name] = meta
        self.values[meta.name] = np.zeros(self.n_nodes, dtype=np.float64)

    def set(self, name: str, node: int, value: float):
        self.values[name][node] = value

    def add(self, name: str, node: int, delta: float):
        self.values[name][node] += delta

    def set_all(self, name: str, values: np.ndarray):
        self.values[name][:] = values

    def snapshot(self) -> Dict[str, np.ndarray]:
        return {k: v.copy() for k, v in self.values.items()}

    @property
    def n_metrics(self) -> int:
        return len(self.meta)


class TimeSeriesStore:
    """Column store: metric -> (n_ticks, n_nodes) array.  VictoriaMetrics
    stand-in; everything the precursor analysis needs is window queries."""

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.ticks: List[float] = []
        self.data: Dict[str, List[np.ndarray]] = {}

    def append(self, t: float, snapshot: Dict[str, np.ndarray]):
        self.ticks.append(t)
        for name, vals in snapshot.items():
            self.data.setdefault(name, []).append(vals)

    def series(self, name: str) -> np.ndarray:
        return np.asarray(self.data[name])          # (n_ticks, n_nodes)

    def window(self, name: str, t0: float, t1: float) -> np.ndarray:
        ts = np.asarray(self.ticks)
        m = (ts >= t0) & (ts < t1)
        return np.asarray(self.data[name])[m]

    def times(self) -> np.ndarray:
        return np.asarray(self.ticks)

    @property
    def names(self):
        return list(self.data)

    def nbytes(self) -> int:
        return sum(len(v) * self.n_nodes * 8 for v in self.data.values())
