"""Prometheus-style metric registry (counters + gauges) with a scrape loop.

The production pipeline in the paper scrapes 4 exporters x 63 nodes at 30 s
intervals into VictoriaMetrics (~751 unique metric names).  This module is
the in-process stand-in: exporters write samples, the registry scrapes into
the time-series store, and the precursor detector reads windows back.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

SCRAPE_INTERVAL_S = 30.0


@dataclass
class MetricMeta:
    name: str
    kind: str            # "counter" | "gauge"
    exporter: str        # dcgm | node | all_smi | backendai
    help: str = ""


class MetricRegistry:
    """Holds current values per (metric, node) and scrapes them into a store."""

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.meta: Dict[str, MetricMeta] = {}
        self.values: Dict[str, np.ndarray] = {}

    def register(self, meta: MetricMeta):
        if meta.name in self.meta:
            return
        self.meta[meta.name] = meta
        self.values[meta.name] = np.zeros(self.n_nodes, dtype=np.float64)

    def set(self, name: str, node: int, value: float):
        self.values[name][node] = value

    def add(self, name: str, node: int, delta: float):
        self.values[name][node] += delta

    def set_all(self, name: str, values: np.ndarray):
        self.values[name][:] = values

    def snapshot(self) -> Dict[str, np.ndarray]:
        return {k: v.copy() for k, v in self.values.items()}

    @property
    def n_metrics(self) -> int:
        return len(self.meta)


class TimeSeriesStore:
    """Column store: metric -> (n_ticks, n_nodes) array.  VictoriaMetrics
    stand-in; everything the precursor analysis needs is window queries.

    Internally each metric holds a list of 2-D chunks — one row per
    single-tick ``append``, one multi-row block per ``append_batch`` — and
    ``series`` consolidates lazily, so batched producers never pay a
    per-tick Python cost."""

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.ticks: List[float] = []
        self.data: Dict[str, List[np.ndarray]] = {}   # name -> 2-D chunks

    def append(self, t: float, snapshot: Dict[str, np.ndarray]):
        self.ticks.append(t)
        for name, vals in snapshot.items():
            arr = np.asarray(vals)
            self.data.setdefault(name, []).append(arr.reshape(1, -1))

    def append_batch(self, ts: np.ndarray, snapshot: Dict[str, np.ndarray]):
        """Append a whole span at once: ``ts`` (T,), values (T, n_nodes)."""
        if len(ts) == 0:
            return
        self.ticks.extend(float(t) for t in ts)
        for name, vals in snapshot.items():
            arr = np.asarray(vals)
            self.data.setdefault(name, []).append(arr)

    def series(self, name: str) -> np.ndarray:
        chunks = self.data[name]
        if len(chunks) > 1:                         # consolidate + cache
            self.data[name] = chunks = [np.concatenate(chunks, axis=0)]
        return chunks[0]                            # (n_ticks, n_nodes)

    def window(self, name: str, t0: float, t1: float) -> np.ndarray:
        ts = np.asarray(self.ticks)
        m = (ts >= t0) & (ts < t1)
        return self.series(name)[m]

    def times(self) -> np.ndarray:
        return np.asarray(self.ticks)

    @property
    def names(self):
        return list(self.data)

    def nbytes(self) -> int:
        return sum(c.nbytes for v in self.data.values() for c in v)
