"""Per-rank sharded data pipeline — the §3.5 fix, built in.

The paper's cross-organizational debugging case: training init took >8 h
because 60 nodes issued fragmented small random I/O against shared files,
saturating the storage metadata service; per-rank file sharding (Arrow files
partitioned by rank) + readahead cut it to <8 min.

This pipeline therefore writes ONE shard file per data-parallel rank at
dataset build time, and each rank streams only its own files sequentially.
``benchmarks/bench_io_sharding`` quantifies the contention cliff of the
shared-file layout vs this one using the metadata-service model below.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    tokens_per_shard: int = 1 << 22
    seed: int = 0


# ---------------------------------------------------------------------------
# dataset build: one file per rank (the fix)
# ---------------------------------------------------------------------------

def build_sharded_dataset(root, n_ranks: int, cfg: DataConfig,
                          n_tokens_per_rank: Optional[int] = None) -> dict:
    """Materialise a synthetic token dataset as per-rank shard files."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    n_tokens_per_rank = n_tokens_per_rank or cfg.tokens_per_shard
    manifest = {"n_ranks": n_ranks, "seq_len": cfg.seq_len,
                "vocab_size": cfg.vocab_size, "files": {}}
    for rank in range(n_ranks):
        rng = np.random.default_rng(cfg.seed * 100_003 + rank)
        toks = rng.integers(0, cfg.vocab_size,
                            size=n_tokens_per_rank, dtype=np.int32)
        f = root / f"shard_{rank:05d}.bin"
        toks.tofile(f)
        manifest["files"][str(rank)] = f.name
    (root / "manifest.json").write_text(json.dumps(manifest))
    return manifest


class RankShardReader:
    """Sequential reader over this rank's own shard (readahead-friendly)."""

    def __init__(self, root, rank: int, cfg: DataConfig,
                 batch_per_rank: int):
        self.root = Path(root)
        manifest = json.loads((self.root / "manifest.json").read_text())
        if str(rank) not in manifest["files"]:
            raise KeyError(f"rank {rank} has no shard "
                           f"(built for {manifest['n_ranks']} ranks)")
        self.tokens = np.fromfile(self.root / manifest["files"][str(rank)],
                                  dtype=np.int32)
        self.cfg = cfg
        self.batch = batch_per_rank
        self._pos = 0

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        need = self.batch * (self.cfg.seq_len + 1)
        if self._pos + need > len(self.tokens):
            self._pos = 0                       # wrap (epoch boundary)
        flat = self.tokens[self._pos:self._pos + need]
        self._pos += need
        arr = flat.reshape(self.batch, self.cfg.seq_len + 1)
        return {"tokens": arr[:, :-1].copy(), "labels": arr[:, 1:].copy()}


def synthetic_stream(cfg: DataConfig, batch: int, seed: int = 0
                     ) -> Iterator[dict]:
    """In-memory fallback stream (tests / tiny examples)."""
    rng = np.random.default_rng(seed)
    while True:
        arr = rng.integers(0, cfg.vocab_size,
                           size=(batch, cfg.seq_len + 1), dtype=np.int32)
        yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


# ---------------------------------------------------------------------------
# metadata-service contention model (the §3.5 bottleneck, quantified)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MetadataServiceModel:
    """Distributed-FS metadata service under concurrent open/lookup load.

    Service rate degrades superlinearly once concurrent lookups exceed
    capacity (lock contention on the shared namespace — what VAST/Upstage/
    Lablup diagnosed jointly).  Defaults roughly calibrated so that the
    shared-small-file layout at 60 nodes lands at the paper's >8 h init
    while per-rank sharding lands at ~8 min.
    """
    base_lookup_s: float = 0.002          # uncontended metadata op
    capacity_ops_s: float = 8_000.0       # aggregate service capacity
    contention_exp: float = 2.0           # superlinear penalty beyond capacity

    def lookup_time_s(self, concurrent_ops_s: float) -> float:
        if concurrent_ops_s <= self.capacity_ops_s:
            return self.base_lookup_s
        over = concurrent_ops_s / self.capacity_ops_s
        return self.base_lookup_s * (over ** self.contention_exp)


def init_time_model(n_nodes: int, files_per_node: int, ops_per_file: int,
                    data_bytes_per_node: float,
                    seq_read_bw: float = 4.5e9,
                    frag_read_bw: float = 0.35e9,
                    md: MetadataServiceModel = MetadataServiceModel(),
                    sharded: bool = True) -> float:
    """Initialization wall-time (s) for one node under either layout.

    shared layout: every node touches every file (n_nodes x files metadata
    storm) and reads are fragmented random I/O;
    sharded layout: each node opens only its own files and streams.
    """
    if sharded:
        n_lookups = files_per_node * ops_per_file
        rate = n_nodes * n_lookups / 60.0           # spread over a minute
        md_time = n_lookups * md.lookup_time_s(rate)
        return md_time + data_bytes_per_node / seq_read_bw
    total_files = files_per_node * n_nodes          # the shared pool
    n_lookups = total_files * ops_per_file          # every node walks all
    rate = n_nodes * n_lookups / 60.0
    md_time = n_lookups * md.lookup_time_s(rate)
    return md_time + data_bytes_per_node / frag_read_bw
