"""Sharding rules: FSDP x TP x (HSDP | pod-FSDP).

Layout (DESIGN.md §6):
* ``model`` axis (16): tensor parallelism — Megatron-style column/row split
  for attention and FFN weights, expert parallelism for MoE stacks, vocab
  parallelism for embeddings.
* ``data`` axis (16): FSDP (ZeRO-3) parameter/optimizer sharding + batch DP.
* ``pod``  axis (2, multi-pod only): HSDP replica axis — parameters are
  REPLICATED across pods (paper-faithful: Solar Open ran HSDP sharding-group
  x replicas, Table 5), gradients all-reduce across pods.  ``fsdp_pods=True``
  extends FSDP across the pod axis instead (beyond-paper lever).

All helpers are divisibility-aware with graceful fallback (e.g. granite's
vocab 49155 shards on d_model instead) so every (arch x shape x mesh) cell
lowers.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Row-parallel leaves: contraction (input) dim carries the model axis so the
# matmul output needs a single psum and no resharding of the input.
_ROW_PARALLEL = {"wo", "w_down", "out_proj", "Wo", "cm_Wv"}
# Never shard (small vectors/scalars whose gather cost exceeds their size).
_REPLICATED = {"first", "gate_attn", "gate_ffn", "dt_bias", "conv_b", "D"}


def _path_names(path) -> list:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(str(e.idx))
        elif hasattr(e, "name"):
            names.append(str(e.name))
    return names


def _prod(xs):
    return math.prod(xs) if xs else 1


class ShardingRules:
    def __init__(self, mesh: Mesh, *, fsdp_pods: bool = False):
        self.mesh = mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.model_axis = "model"
        self.model_size = sizes.get("model", 1)
        if "pod" in sizes and fsdp_pods:
            self.data_axes: tuple = ("pod", "data")
        else:
            self.data_axes = ("data",)
        self.data_size = _prod([sizes[a] for a in self.data_axes])
        self.batch_axes: tuple = tuple(a for a in ("pod", "data")
                                       if a in sizes)
        self.batch_size_axes = _prod([sizes[a] for a in self.batch_axes])

    # -- parameters --------------------------------------------------------

    def param_pspec(self, path, leaf) -> P:
        names = _path_names(path)
        shape = leaf.shape
        ndim = len(shape)
        spec: list = [None] * ndim
        leaf_name = names[-1] if names else ""
        if ndim == 0 or leaf_name in _REPLICATED:
            return P(*spec)

        # pick the model (TP/EP) dim
        model_dim: Optional[int] = None
        if "moe" in names and ndim == 3:
            if shape[0] % self.model_size == 0:
                model_dim = 0            # expert parallelism
        if model_dim is None and ndim >= 2:
            if leaf_name in _ROW_PARALLEL:
                prefs = list(range(ndim - 1)) + [ndim - 1]
            elif leaf_name == "embed":
                prefs = [0, 1]           # vocab-parallel, fallback d_model
            else:
                prefs = [ndim - 1] + list(range(ndim - 1))
            for d in prefs:
                if shape[d] % self.model_size == 0:
                    model_dim = d
                    break
        if model_dim is not None:
            spec[model_dim] = self.model_axis

        # FSDP dim: first remaining divisible dim
        if ndim >= 2 or (ndim == 1 and shape[0] >= 1 << 16):
            for d in range(ndim):
                if d == model_dim:
                    continue
                if shape[d] % self.data_size == 0:
                    spec[d] = self.data_axes if len(self.data_axes) > 1 \
                        else self.data_axes[0]
                    break
        return P(*spec)

    def params_shardings(self, params_shapes):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: NamedSharding(self.mesh,
                                             self.param_pspec(path, leaf)),
            params_shapes)

    def opt_shardings(self, opt_shapes, params_shapes):
        """Optimizer states mirror parameter sharding; scalars replicated."""

        def match(path, leaf):
            if len(leaf.shape) == 0:
                return NamedSharding(self.mesh, P())
            return NamedSharding(self.mesh, self.param_pspec(path[1:], leaf))
        # opt state tree = AdamWState(step, mu, nu); mu/nu mirror params.
        return jax.tree_util.tree_map_with_path(match, opt_shapes)

    # -- batches -----------------------------------------------------------

    def batch_pspec(self, shape) -> P:
        spec: list = [None] * len(shape)
        if shape and shape[0] % self.batch_size_axes == 0:
            spec[0] = self.batch_axes if len(self.batch_axes) > 1 \
                else self.batch_axes[0]
        elif len(shape) >= 2 and shape[1] % self.batch_size_axes == 0:
            spec[1] = self.batch_axes if len(self.batch_axes) > 1 \
                else self.batch_axes[0]   # batch=1 long-context: shard seq
        # last dim (d_model / vocab) over model when divisible
        if len(shape) >= 3 and shape[-1] % self.model_size == 0:
            spec[-1] = self.model_axis
        return P(*spec)

    def batch_shardings(self, batch_shapes):
        return jax.tree.map(
            lambda leaf: NamedSharding(self.mesh, self.batch_pspec(leaf.shape)),
            batch_shapes)

    # -- kv / recurrent caches ----------------------------------------------

    def cache_pspec(self, path, leaf) -> P:
        names = _path_names(path)
        shape = leaf.shape
        ndim = len(shape)
        off = 1 if "period" in names else 0   # stacked (n_periods, ...) leaves
        spec: list = [None] * ndim
        leaf_name = names[-1] if names else ""

        batch_axes = self.batch_axes if len(self.batch_axes) > 1 \
            else self.batch_axes[0]
        b_dim = off + 0

        if leaf_name in ("k", "v") and ndim >= off + 4:
            # KV cache (B, S, n_kv, head_dim): batch -> data axes; model axis
            # on kv-heads when divisible, otherwise on the SEQUENCE dim
            # (flash-decoding split — sharding head_dim caused involuntary
            # full rematerialization in the SPMD partitioner; measured).
            seq_dim, kv_dim = off + 1, off + 2
            if shape[b_dim] % self.batch_size_axes == 0:
                spec[b_dim] = batch_axes
                if shape[kv_dim] % self.model_size == 0:
                    spec[kv_dim] = self.model_axis
                elif shape[seq_dim] % self.model_size == 0 \
                        and shape[seq_dim] >= 4 * self.model_size:
                    spec[seq_dim] = self.model_axis
            else:
                # batch=1 long-context: context-parallel cache
                if shape[kv_dim] % self.model_size == 0:
                    if shape[seq_dim] % self.batch_size_axes == 0:
                        spec[seq_dim] = batch_axes
                    spec[kv_dim] = self.model_axis
                elif shape[seq_dim] % (self.batch_size_axes
                                       * self.model_size) == 0:
                    axes = tuple(self.batch_axes) + (self.model_axis,)
                    spec[seq_dim] = axes
                elif shape[seq_dim] % self.batch_size_axes == 0:
                    spec[seq_dim] = batch_axes
            return P(*spec)

        if shape[b_dim] % self.batch_size_axes == 0:
            spec[b_dim] = batch_axes

        model_dim_by_leaf = {
            "wkv": off + 1,                    # rwkv head dim
            "shift": off + 1, "cm": off + 1,   # d_model
            "conv": off + 2, "ssm": off + 1,   # d_inner
        }
        d = model_dim_by_leaf.get(leaf_name)
        if d is not None and d < ndim and shape[d] % self.model_size == 0 \
                and spec[d] is None:
            spec[d] = self.model_axis
        return P(*spec)

    def cache_shardings(self, cache_shapes):
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: NamedSharding(self.mesh,
                                             self.cache_pspec(path, leaf)),
            cache_shapes)

    # -- scalars -------------------------------------------------------------

    def replicated(self):
        return NamedSharding(self.mesh, P())
