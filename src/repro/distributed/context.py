"""Trace-time activation-sharding context.

Model code is mesh-agnostic; the step builders install this context while
tracing under a mesh, and the model calls ``shard_batch`` on its residual
stream.  Without a context the calls are no-ops (CPU smoke tests,
single-device runs).

Why this exists: GSPMD propagation loses the batch sharding through the
vocab-sharded embedding gather (measured: attention ran with batch
replicated over the ``data`` axis -> 16x FLOP inflation; EXPERIMENTS.md
§Perf iteration 2), so the residual stream is re-pinned after embedding and
at each period boundary.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_tls = threading.local()


class ActivationSharding:
    def __init__(self, batch_axes: Tuple[str, ...], batch_size: int,
                 model_axis: str, model_size: int, mesh=None):
        self.batch_axes = batch_axes
        self.batch_size = batch_size          # product of batch axis sizes
        self.model_axis = model_axis
        self.model_size = model_size
        self.mesh = mesh                      # for explicit shard_map users

    @property
    def batch_spec_entry(self):
        return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]


def current() -> Optional[ActivationSharding]:
    return getattr(_tls, "ctx", None)


@contextmanager
def activation_sharding(rules):
    """``rules``: a distributed.sharding.ShardingRules instance."""
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = ActivationSharding(
        batch_axes=rules.batch_axes, batch_size=rules.batch_size_axes,
        model_axis="model", model_size=rules.model_size, mesh=rules.mesh)
    try:
        yield
    finally:
        _tls.ctx = prev


def shard_batch(x, batch_dim: int = 0, model_dim: Optional[int] = None):
    """Pin ``batch_dim`` to the batch axes (divisibility-checked); optionally
    pin ``model_dim`` to the model axis.  batch=1 inputs fall back to
    sharding dim 1 (sequence/context parallelism)."""
    ctx = current()
    if ctx is None or ctx.batch_size <= 1:
        return x
    spec = [None] * x.ndim
    placed = False
    if x.shape[batch_dim] % ctx.batch_size == 0:
        spec[batch_dim] = ctx.batch_spec_entry
        placed = True
    elif x.ndim >= 2 and batch_dim == 0 \
            and x.shape[1] % ctx.batch_size == 0 and x.shape[1] > 1:
        spec[1] = ctx.batch_spec_entry
        placed = True
    if model_dim is not None and ctx.model_size > 1 \
            and x.shape[model_dim] % ctx.model_size == 0 \
            and spec[model_dim] is None:
        spec[model_dim] = ctx.model_axis
        placed = True
    if not placed:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_experts(x, expert_dim: int = 0, token_dim: int = 1):
    """Pin MoE dispatch tensors: experts -> model axis (EP), capacity
    tokens -> batch axes.  Without this GSPMD replicates the (E, C, d)
    dispatch across the mesh (measured: 16 TB/device/step of all-gather on
    the 102B MoE — EXPERIMENTS.md §Perf iteration 3)."""
    ctx = current()
    if ctx is None:
        return x
    spec = [None] * x.ndim
    placed = False
    if ctx.model_size > 1 and x.shape[expert_dim] % ctx.model_size == 0:
        spec[expert_dim] = ctx.model_axis
        placed = True
    if token_dim is not None and ctx.batch_size > 1 \
            and x.shape[token_dim] % ctx.batch_size == 0:
        spec[token_dim] = ctx.batch_spec_entry
        placed = True
    if not placed:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
