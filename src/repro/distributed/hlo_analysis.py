"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis()`` provides HLO_FLOPs and HLO_bytes; collective traffic is
NOT in cost_analysis, so we parse the (post-SPMD, per-device) HLO text and
sum the result-shape bytes of every collective op, bucketed by kind.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# one shape literal:  bf16[8,128]{1,0:T(8,128)}  /  f32[]  /  u32[4]
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# an HLO instruction line: "  %name = <result-type> op-name(...)"
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+([a-z0-9\-]+)\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes by collective kind from (post-SPMD) HLO text."""
    out: Dict[str, int] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        result_type, op = m.group(1), m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            out[base] = out.get(base, 0) + _shape_bytes(result_type)
    return out


_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](T\(([0-9,]+)\))?")


def _iota_group_members(g, rows, cols, transposed):
    """Members of group ``g`` for iota replica_groups [rows,cols]<=[N]."""
    if not transposed:
        return range(g * cols, (g + 1) * cols)
    return range(g, rows * cols, rows)


def _spans_pods(line: str, pod_size: int) -> bool:
    """Whether a collective's replica groups cross the pod boundary."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        rows, cols = int(m.group(1)), int(m.group(2))
        transposed = m.group(4) is not None
        for g in range(rows):
            pods = {d // pod_size
                    for d in _iota_group_members(g, rows, cols, transposed)}
            if len(pods) > 1:
                return True
        return False
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            devs = [int(x) for x in grp.replace("{", "").replace("}", "")
                    .split(",") if x.strip()]
            if len({d // pod_size for d in devs}) > 1:
                return True
        return False
    return False


def collective_bytes_by_span(hlo_text: str, pod_size: int = 256
                             ) -> Dict[str, int]:
    """Per-device collective bytes split into in-pod vs cross-pod traffic
    (cross-pod = any replica group spans the pod boundary).  Quantifies the
    HSDP locality advantage that raw byte totals hide."""
    out = {"in_pod": 0, "cross_pod": 0}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        nbytes = _shape_bytes(m.group(1))
        key = "cross_pod" if _spans_pods(line, pod_size) else "in_pod"
        out[key] += nbytes
    return out


def count_collective_ops(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        op = m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            out[base] = out.get(base, 0) + 1
    return out


@dataclass
class Roofline:
    """Three-term roofline for one (arch x shape x mesh) cell."""
    flops: float                 # whole-module HLO FLOPs (global)
    hbm_bytes: float             # whole-module bytes accessed (global)
    coll_bytes_per_device: float
    chips: int
    coll_breakdown: Dict[str, int] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # per-device bytes over the chip's ICI link bandwidth == the
        # prompt's collective_bytes/(chips*link_bw) with cluster-total bytes
        return self.coll_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "coll_breakdown": self.coll_breakdown,
        }
